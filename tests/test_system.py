"""End-to-end behaviour tests for the HSGD system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import FederationConfig, TrainConfig
from repro.core.baselines import make_runner, merge_groups_for_tdcd
from repro.core.hsgd import (
    HSGDRunner,
    global_model,
    init_state,
    make_group_weights,
)
from repro.core.metrics import evaluate_global
from repro.data.partition import hybrid_partition
from repro.data.synthetic import DATASETS, ORGANAMNIST, flatten_for_tower, make_dataset, vertical_split
from repro.models.split_model import cnn_hybrid, lstm_hybrid


def _setup(spec=ORGANAMNIST, n=256, groups=4, devices=16, alpha=0.5, q=2, p=4, lr=0.05):
    fed = FederationConfig(num_groups=groups, devices_per_group=devices, alpha=alpha,
                           local_interval=q, global_interval=p)
    train = TrainConfig(learning_rate=lr)
    X, y = make_dataset(spec, n, seed=0)
    fdata = hybrid_partition(spec, X, y, fed, seed=0)
    data = {k: jnp.asarray(v) for k, v in fdata.stacked().items()}
    if spec.name == "organamnist":
        model = cnn_hybrid(h_rows=11, n_classes=spec.n_classes)
    else:
        model = lstm_hybrid(n_features=X.shape[-1] if spec.split_axis == 1 else X.shape[1],
                            hospital_features=spec.hospital_size, n_classes=spec.n_classes)
    return model, fed, train, data, (X, y)


def test_hsgd_loss_decreases():
    model, fed, train, data, _ = _setup()
    runner = HSGDRunner(model, fed, train)
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    w = make_group_weights(data)
    state, losses = runner.run(state, data, w, rounds=8)
    assert losses[-1] < losses[0] * 0.7
    assert not np.isnan(np.asarray(losses)).any()


def test_hsgd_equals_centralized_sgd_when_degenerate():
    """M=1, α=1, P=Q=1 must reproduce joint mini-batch SGD exactly."""
    spec = ORGANAMNIST
    fed = FederationConfig(num_groups=1, devices_per_group=16, alpha=1.0,
                           local_interval=1, global_interval=1)
    train = TrainConfig(learning_rate=0.05)
    X, y = make_dataset(spec, 16, seed=0)
    fdata = hybrid_partition(spec, X, y, fed, seed=0)
    data = {k: jnp.asarray(v) for k, v in fdata.stacked().items()}
    model = cnn_hybrid(h_rows=11)
    runner = HSGDRunner(model, fed, train)
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    w = make_group_weights(data)
    params = global_model(state, w)
    state, _ = runner.run(state, data, w, rounds=5)
    gm = global_model(state, w)
    # manual joint SGD
    for _ in range(5):
        g = jax.grad(lambda p: model.full_loss(p, data["x1"][0], data["x2"][0], data["y"][0]))(params)
        params = jax.tree.map(lambda p_, g_: p_ - 0.05 * g_, params, g)
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), gm, params)
    assert max(jax.tree_util.tree_leaves(diffs)) < 1e-4


@pytest.mark.parametrize("algo", ["hsgd", "c-hsgd", "jfl", "tdcd", "c-tdcd", "centralized"])
def test_all_algorithms_run_and_learn(algo):
    model, fed, train, data, _ = _setup(n=128, groups=2, devices=8, q=1, p=2)
    runner, eff_fed = make_runner(algo, model, fed, train)
    if algo in ("tdcd", "c-tdcd", "centralized"):
        raw = merge_groups_for_tdcd({k: np.asarray(v) for k, v in data.items()})
        data = {k: jnp.asarray(v) for k, v in raw.items()}
    w = make_group_weights(data)
    if algo == "jfl":
        state = runner.init(jax.random.PRNGKey(0))
    else:
        state = init_state(jax.random.PRNGKey(0), model, eff_fed, data)
    state, losses = runner.run(state, data, w, rounds=6)
    assert losses[-1] < losses[0]
    assert not np.isnan(np.asarray(losses)).any()


def test_global_model_metrics_complete():
    model, fed, train, data, (X, y) = _setup(n=128, groups=2, devices=8)
    runner = HSGDRunner(model, fed, train)
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    w = make_group_weights(data)
    state, _ = runner.run(state, data, w, rounds=3)
    gm = global_model(state, w)
    spec = ORGANAMNIST
    X1, X2 = vertical_split(spec, X)
    m = evaluate_global(model, gm, flatten_for_tower(spec, X1), flatten_for_tower(spec, X2), y)
    for k in ("loss", "accuracy", "precision", "recall", "f1", "auc_roc"):
        assert k in m and np.isfinite(m[k])
    assert 0.0 <= m["auc_roc"] <= 1.0


def test_lstm_pipeline_mimic_shapes():
    spec = DATASETS["mimic3"]
    model, fed, train, data, _ = _setup(spec=spec, n=64, groups=2, devices=8, q=1, p=1)
    runner = HSGDRunner(model, fed, train)
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    w = make_group_weights(data)
    state, losses = runner.run(state, data, w, rounds=4)
    assert np.isfinite(losses).all()


def test_convergence_to_target_accuracy():
    """HSGD reaches a clearly-learned AUC on the non-iid 3-tier split
    (paper's 'achieves the desired accuracy' claim at small scale).

    Ceiling note: with 4 label-skewed groups (2 dominant labels each) the
    averaged global model plateaus near 0.82 macro-AUC on 11 classes at this
    tiny scale (chance = 0.5) — the threshold asserts genuine federated
    learning, not the paper's full-size accuracy.
    """
    model, fed, train, data, (X, y) = _setup(n=512, groups=4, devices=32,
                                             alpha=0.5, q=1, p=1, lr=0.02)
    runner = HSGDRunner(model, fed, train)
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    w = make_group_weights(data)
    state, losses = runner.run(state, data, w, rounds=60)
    gm = global_model(state, w)
    spec = ORGANAMNIST
    X1, X2 = vertical_split(spec, X)
    m = evaluate_global(model, gm, flatten_for_tower(spec, X1), flatten_for_tower(spec, X2), y)
    assert m["auc_roc"] > 0.78, m
    assert m["accuracy"] > 2.0 / 11.0, m  # well above chance
